"""Deprecated shim — the serving loop now lives in
``repro.serve.service.QueryService``.

The monolithic ``QueryServer`` (fixed-size batches, pad-the-tail per
flush, unbounded result retention) was re-architected into the layered
service tier: admission queue → hot-pair cache → micro-batcher →
``make_answer_fn``. This module keeps the old names importable with
the full legacy surface (``submit``/``flush``/``warmup``/``stats``/
``stats_``) so downstream callers keep working while they migrate —
constructing one warns, exactly like the PR-4 engine-layer shims.

Differences from the historical class are bug fixes, not behavior
drift:

- ``flush`` no longer retains every result array forever (the old
  ``self._results`` list grew without bound on a long-lived server);
- empty-percentile summaries report ``nan`` instead of a fabricated
  ``0.0`` (``ServerStats`` is now :class:`repro.serve.ServiceStats`).

Prefer ``CHLIndex.serve`` (returns a :class:`QueryService`).
"""

from __future__ import annotations

import warnings
from typing import Optional

from repro.core.labels import LabelTable
from repro.serve import backends
from repro.serve.service import QueryService
from repro.serve.stats import ServiceStats

#: legacy name — the accounting surface is the service tier's
ServerStats = ServiceStats


class QueryServer(QueryService):
    """Deprecated alias of :class:`repro.serve.QueryService`."""

    def __init__(self, answer, batch_size: int = 1024,
                 drop_first: bool = True, **kw):
        warnings.warn(
            "QueryServer is deprecated; use CHLIndex.serve (a "
            "QueryService) instead", DeprecationWarning, stacklevel=2)
        super().__init__(answer, batch_size=batch_size,
                         drop_first=drop_first, **kw)

    @staticmethod
    def build(table: LabelTable, mode: str = "qlsn",
              mesh=None, partitioned: Optional[LabelTable] = None,
              batch_size: int = 1024, rank=None) -> "QueryServer":
        """Deprecated shim — use ``repro.index.CHLIndex.serve``."""
        warnings.warn(
            "QueryServer.build is a deprecated engine-layer shim; "
            "serve through repro.index (build(...).serve(mode=...))",
            DeprecationWarning, stacklevel=2)
        fn = backends.make_answer_fn(table, mode, mesh=mesh,
                                     partitioned=partitioned, rank=rank)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return QueryServer(fn, batch_size=batch_size)
