"""Subprocess crash-kill harness.

The recovery guarantees in this repo are pinned by *really killing*
processes, not by raising exceptions the code under test could
accidentally catch: a child process runs the production code path with
a :class:`~repro.ft.inject.FaultPlan` delivered through the
``REPRO_FAULT_PLAN`` environment variable, a ``Fault("crash",
hard=True)`` drops it with ``os._exit(FAULT_EXIT_CODE)`` at the named
site (no unwinding, no atexit, no flushing — the moral equivalent of
``kill -9``), and the parent then resumes/reloads and asserts the
recovered labels are **bit-identical** to an uninterrupted run.

Bit-identity is asserted over the *loaded arrays*, not the artifact
bytes: ``.npz`` members embed zip timestamps, so byte-comparing files
across runs is meaningless while array-comparing them is exact.

Used by ``tests/test_ft.py`` and the CI fault-injection smoke
(``repro.launch.ft_smoke``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.ft.inject import ENV_PLAN, FAULT_EXIT_CODE, FaultPlan
from repro.index.store import shard_filename


def run_child(args: List[str], *, plan: Optional[FaultPlan] = None,
              env: Optional[Dict[str, str]] = None,
              timeout: float = 900.0) -> subprocess.CompletedProcess:
    """Run ``python <args...>`` with ``plan`` installed via the
    environment (inherits the parent's env, so ``PYTHONPATH`` et al.
    carry over)."""
    e = dict(os.environ)
    if env:
        e.update(env)
    if plan is not None:
        e[ENV_PLAN] = plan.to_json()
    else:
        e.pop(ENV_PLAN, None)
    return subprocess.run([sys.executable, *args], env=e,
                          capture_output=True, text=True,
                          timeout=timeout)


def _tail(text: str, lines: int = 20) -> str:
    return "\n".join(text.strip().splitlines()[-lines:])


def assert_child_ok(proc: subprocess.CompletedProcess) -> None:
    if proc.returncode != 0:
        raise AssertionError(
            f"child exited {proc.returncode}, expected 0\n"
            f"stdout:\n{_tail(proc.stdout)}\n"
            f"stderr:\n{_tail(proc.stderr)}")


def assert_child_killed(proc: subprocess.CompletedProcess) -> None:
    """The child must have died at the injected fault site — exit code
    ``FAULT_EXIT_CODE``, not a clean exit (fault never fired) and not
    a generic failure (died somewhere else)."""
    if proc.returncode != FAULT_EXIT_CODE:
        raise AssertionError(
            f"child exited {proc.returncode}, expected injected-crash "
            f"exit {FAULT_EXIT_CODE}\n"
            f"stdout:\n{_tail(proc.stdout)}\n"
            f"stderr:\n{_tail(proc.stderr)}")


def index_arrays(directory: str) -> Dict[str, np.ndarray]:
    """Every array of a saved v2 artifact, keyed ``rank`` /
    ``shard_<k>/<name>`` — the bit-identity comparison surface."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    out = {"rank": np.load(os.path.join(directory, "rank.npy"))}
    for k in range(int(manifest["store"]["shards"])):
        with np.load(os.path.join(directory, shard_filename(k))) as z:
            for name in z.files:
                out[f"shard_{k}/{name}"] = z[name]
    return out


def assert_index_bit_identical(got_dir: str, want_dir: str) -> None:
    got = index_arrays(got_dir)
    want = index_arrays(want_dir)
    if set(got) != set(want):
        raise AssertionError(
            f"artifact array sets differ: only-got="
            f"{sorted(set(got) - set(want))} only-want="
            f"{sorted(set(want) - set(got))}")
    for key in sorted(got):
        if not np.array_equal(got[key], want[key]):
            raise AssertionError(
                f"{key} differs between {got_dir} and {want_dir} — "
                "recovery is NOT bit-identical")
