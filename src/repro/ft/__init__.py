from repro.ft.elastic import (HeartbeatMonitor, lost_roots,
                              reshard_state, restore_elastic)
