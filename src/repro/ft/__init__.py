"""`repro.ft` — fault tolerance: injection, durability, elasticity.

Two halves:

- :mod:`repro.ft.inject` — deterministic fault injection behind named
  ``fault_site`` hooks threaded through every durability-critical
  write/read in the repo (checkpoint commits, artifact save/load,
  engine commits, the repair merge, spill reads, the serve answer
  path), plus the bounded-retry wrapper those paths use for transient
  I/O. :mod:`repro.ft.harness` drives real subprocesses through crash
  plans and asserts recovery lands bit-identical labels.
- :mod:`repro.ft.elastic` — node loss and re-meshing: checkpoint
  restore onto a different mesh, lost-root collection for re-PLaNTing
  (the paper's §5.2 independence property as a recovery mechanism),
  and the host-side :class:`HeartbeatMonitor` failure detector wired
  into ``repro.engine.dist``.
"""

from repro.ft.elastic import (HeartbeatMonitor, lost_roots,
                              reshard_state, restore_elastic)
from repro.ft.inject import (ENV_PLAN, FAULT_EXIT_CODE, FAULT_KINDS,
                             KNOWN_SITES, Fault, FaultPlan,
                             InjectedCrash, TransientIOError,
                             fault_site, faults, flip_bits, install,
                             torn_write, with_retries)

__all__ = [
    "ENV_PLAN", "FAULT_EXIT_CODE", "FAULT_KINDS", "KNOWN_SITES",
    "Fault", "FaultPlan", "HeartbeatMonitor", "InjectedCrash",
    "TransientIOError", "fault_site", "faults", "flip_bits",
    "install", "lost_roots", "reshard_state", "restore_elastic",
    "torn_write", "with_retries",
]
