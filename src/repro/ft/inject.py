"""Deterministic fault injection behind named sites.

Every durability-critical write or read in the repo passes through a
named :func:`fault_site` hook (the registry below). A seeded
:class:`FaultPlan` maps site names to fault actions, so a test — or a
subprocess crash-kill harness — can make the *production* code path
crash at a checkpoint commit, tear a shard file mid-write, flip a bit
in an artifact, fail transiently with ``OSError``, or stall, all
reproducibly:

    plan = FaultPlan({"checkpoint.commit": [Fault("crash", after=2)]})
    with faults(plan):
        build(...)          # raises InjectedCrash at the 3rd commit

Subprocesses activate a plan through the ``REPRO_FAULT_PLAN``
environment variable (the JSON of :meth:`FaultPlan.to_json`) — that is
how ``repro.ft.harness`` kills a real child process at a named site
(``Fault("crash", hard=True)`` → ``os._exit(FAULT_EXIT_CODE)``, the
moral equivalent of ``kill -9``: no atexit, no flushing, no cleanup).

With no plan installed, ``fault_site`` is a no-op costing one
attribute load and one dict probe — cheap enough for the engine's
per-superstep commit path.

The module also owns :func:`with_retries`, the bounded
retry-with-backoff wrapper the durability layers use around transient
I/O; an injected :class:`TransientIOError` is an ``OSError``, so a
fault plan exercises the retry path of the real callers.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: exit status of a hard injected crash — distinguishable from normal
#: failures (1) and signals, so the harness can assert the child died
#: at the fault site and not somewhere else
FAULT_EXIT_CODE = 41

#: environment variable a child process reads its plan from
ENV_PLAN = "REPRO_FAULT_PLAN"

#: the instrumented sites. A FaultPlan naming anything else is a typo
#: and is rejected at construction.
KNOWN_SITES = (
    "checkpoint.write",       # CheckpointManager._write, arrays.npz on disk
    "checkpoint.commit",      # CheckpointManager._write, before the rename
    "engine.commit",          # engine.runner superstep commit, before save
    "artifact.save.shard",    # CHLIndex.save, one shard file on disk
    "artifact.save.commit",   # CHLIndex.save, before the staged swap
    "artifact.load.shard",    # open_npz_arrays, before parsing a shard
    "quant.encode.shard",     # CompressedStore._encode, per shard
    "quant.decode.shard",     # CompressedStore.from_encoded_shards
    "repair.merge",           # dynamic.repair, before the store swap
    "spill.query",            # SpillStore.query_shard, before the read
    "serve.answer",           # QueryService._launch, before the kernel
)

#: fault kinds a plan may schedule
FAULT_KINDS = ("crash", "torn", "bitflip", "io", "latency")


class InjectedCrash(BaseException):
    """A soft injected crash (``hard=False``). Derives from
    ``BaseException`` so no production ``except Exception`` / retry
    wrapper can swallow it — exactly like a real kill."""

    def __init__(self, site: str):
        super().__init__(f"injected crash at fault site {site!r}")
        self.site = site


class TransientIOError(OSError):
    """An injected transient I/O failure (an ``OSError``, so the
    production retry wrappers see exactly what a flaky disk throws)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault at a site.

    ``after``: hits of the site that pass through before the fault
    triggers (0 = the first hit). ``count`` (io only): how many
    consecutive hits raise before the site heals — the knob retry
    tests turn. ``hard`` (crash only): ``os._exit`` instead of raising
    :class:`InjectedCrash`.
    """

    kind: str
    after: int = 0
    count: int = 1
    keep_fraction: float = 0.5       # torn: fraction of bytes kept
    flips: int = 1                   # bitflip: bits to flip
    delay_s: float = 0.0             # latency: injected stall
    hard: bool = False               # crash: os._exit vs InjectedCrash

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one "
                             f"of {FAULT_KINDS}")
        if not 0.0 <= self.keep_fraction < 1.0:
            raise ValueError("keep_fraction must be in [0, 1)")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FaultPlan:
    """A seeded schedule of faults keyed by site name.

    Deterministic twice over: per-site hit counters make *when* a
    fault fires reproducible, and the per-site rng streams (derived
    from ``seed`` + a stable hash of the site name, independent of
    call order across sites) make *what* it does to the bytes
    reproducible.
    """

    def __init__(self, sites: Dict[str, Sequence[Fault]], *,
                 seed: int = 0):
        for name in sites:
            if name not in KNOWN_SITES:
                raise ValueError(
                    f"unknown fault site {name!r}; instrumented sites: "
                    f"{KNOWN_SITES}")
        self.sites: Dict[str, List[Fault]] = {
            name: list(fs) for name, fs in sites.items()}
        self.seed = int(seed)
        self.hits: Dict[str, int] = {name: 0 for name in self.sites}
        self.fired: List[Tuple[str, str]] = []       # (site, kind) log

    # ------------------------------------------------------ plumbing

    def _rng(self, site: str) -> np.random.Generator:
        return np.random.default_rng(
            [self.seed, zlib.crc32(site.encode())])

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "sites": {name: [f.to_dict() for f in fs]
                      for name, fs in self.sites.items()}})

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        spec = json.loads(text)
        return cls({name: [Fault(**f) for f in fs]
                    for name, fs in spec.get("sites", {}).items()},
                   seed=spec.get("seed", 0))

    # -------------------------------------------------------- firing

    def fire(self, site: str, path: Optional[str]) -> None:
        faults = self.sites.get(site)
        if not faults:
            return
        self.hits[site] += 1
        hit = self.hits[site]
        for f in faults:
            if f.kind == "io":
                if not f.after < hit <= f.after + f.count:
                    continue
            elif hit != f.after + 1:
                continue
            self.fired.append((site, f.kind))
            self._trigger(site, f, path)

    def _trigger(self, site: str, f: Fault, path: Optional[str]) -> None:
        if f.kind == "crash":
            if f.hard:
                # a real kill: no unwinding, no atexit, no flushing
                os._exit(FAULT_EXIT_CODE)
            raise InjectedCrash(site)
        if f.kind == "latency":
            time.sleep(f.delay_s)
            return
        if f.kind == "io":
            raise TransientIOError(
                f"injected transient I/O failure at {site!r}"
                + (f" ({path})" if path else ""))
        # file-mutating kinds need the file the site just touched
        if path is None or not os.path.exists(path):
            raise ValueError(
                f"fault {f.kind!r} at site {site!r} needs an on-disk "
                f"path (got {path!r})")
        if f.kind == "torn":
            torn_write(path, f.keep_fraction)
        elif f.kind == "bitflip":
            flip_bits(path, self._rng(site), flips=f.flips)


def torn_write(path: str, keep_fraction: float) -> int:
    """Truncate ``path`` to a prefix — the on-disk shape of a crash
    between ``write()`` and durability. Returns bytes kept."""
    size = os.path.getsize(path)
    keep = max(1, int(size * keep_fraction)) if size else 0
    with open(path, "r+b") as fh:
        fh.truncate(keep)
    return keep


def flip_bits(path: str, rng: np.random.Generator, flips: int = 1
              ) -> List[int]:
    """Flip ``flips`` seeded bit positions in ``path`` (silent media
    corruption); returns the flipped byte offsets."""
    size = os.path.getsize(path)
    if size == 0:
        return []
    offsets = sorted(int(o) for o in
                     rng.integers(0, size, size=flips))
    with open(path, "r+b") as fh:
        for off in offsets:
            fh.seek(off)
            byte = fh.read(1)[0]
            fh.seek(off)
            fh.write(bytes([byte ^ (1 << int(rng.integers(0, 8)))]))
    return offsets


# --------------------------------------------------------------------
# installation: one process-wide active plan
# --------------------------------------------------------------------

_active: Optional[FaultPlan] = None
_env_loaded = False


def install(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` as the process-wide active fault plan
    (``None`` uninstalls)."""
    global _active
    _active = plan


@contextlib.contextmanager
def faults(plan: FaultPlan):
    """Scoped installation: ``with faults(plan): ...``"""
    prev = _active
    install(plan)
    try:
        yield plan
    finally:
        install(prev)


def _plan() -> Optional[FaultPlan]:
    global _env_loaded, _active
    if _active is not None:
        return _active
    if not _env_loaded:
        _env_loaded = True
        text = os.environ.get(ENV_PLAN)
        if text:
            _active = FaultPlan.from_json(text)
    return _active


def fault_site(name: str, path: Optional[str] = None) -> None:
    """The hook production code calls at a named durability-critical
    point. ``path``, when given, is the file the site just wrote (or
    is about to read) — the target of torn/bitflip faults. A no-op
    unless a plan is installed (or ``REPRO_FAULT_PLAN`` is set)."""
    plan = _plan()
    if plan is not None:
        plan.fire(name, path)


# --------------------------------------------------------------------
# bounded retry with backoff — the transient-I/O answer
# --------------------------------------------------------------------

def with_retries(fn: Callable[[], object], *, retries: int = 3,
                 base_delay_s: float = 0.01, max_delay_s: float = 1.0,
                 retry_on: tuple = (OSError,),
                 describe: str = "") -> object:
    """Call ``fn``; on a ``retry_on`` exception retry up to
    ``retries`` times with exponential backoff (capped at
    ``max_delay_s``). The last failure propagates. An
    :class:`InjectedCrash` is a ``BaseException`` and is never
    retried — a crash is a crash."""
    delay = base_delay_s
    for attempt in range(retries + 1):
        try:
            return fn()
        except retry_on:
            if attempt >= retries:
                raise
            time.sleep(delay)
            delay = min(delay * 2, max_delay_s)
    raise AssertionError("unreachable")  # pragma: no cover
