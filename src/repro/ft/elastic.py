"""Fault tolerance: elastic re-meshing, failure handling, stragglers.

At 1000+ node scale the framework must survive: (a) node loss →
restart from the latest atomic checkpoint on a *smaller* mesh;
(b) node gain → rescale up; (c) stragglers → even, deterministic work
assignment plus asynchronous checkpointing off the critical path.

`reshard_state` is the mechanism behind (a)/(b): restoring a
checkpoint onto a different mesh is just `device_put` with the new
shardings (the checkpoint is mesh-agnostic numpy). For CHL runs,
elasticity is even cheaper: PLaNT supersteps are stateless beyond the
label partitions, so a lost node's root queue is simply re-PLaNTed
(zero-communication recovery — the paper's §5.2 property doubles as a
fault-tolerance property, see DESIGN.md §5).

Straggler mitigation implemented here:
- round-robin-by-rank root assignment (`core.dgll.assign_roots`)
  balances tree-size skew across nodes (paper Fig. 2);
- for training, the data pipeline is shard-deterministic so a
  restarted/replaced host rejoins at the exact batch cursor.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

import jax
import numpy as np

if TYPE_CHECKING:   # import cycle: checkpoint.manager uses ft.inject
    from repro.checkpoint.manager import CheckpointManager


def reshard_state(state: Any, shardings: Any) -> Any:
    """Re-place an in-memory state pytree onto new shardings (mesh)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings)


def restore_elastic(mgr: "CheckpointManager", template: Any,
                    shardings: Any, step: Optional[int] = None
                    ) -> Tuple[Any, int, Dict]:
    """Restore the latest checkpoint onto a (possibly different) mesh."""
    return mgr.restore(template, step=step, shardings=shardings)


def lost_roots(queues: np.ndarray, lost_nodes: list[int],
               completed: int) -> np.ndarray:
    """CHL recovery: the not-yet-completed roots of failed nodes.

    ``queues``: the `assign_roots` matrix; ``completed``: number of
    per-node queue positions already committed to stable storage.
    The survivors re-PLaNT these roots (order does not matter for
    correctness — PLaNT trees are independent)."""
    rest = queues[lost_nodes, completed:]
    return rest[rest >= 0]


class HeartbeatMonitor:
    """Host-side failure detector used by the launcher loop: nodes
    report per-superstep progress; nodes silent for ``patience``
    supersteps are declared lost (simulation hook for tests)."""

    def __init__(self, q: int, patience: int = 3):
        self.last_seen = np.zeros(q, dtype=np.int64)
        self.patience = patience

    def report(self, node: int, superstep: int) -> None:
        self.last_seen[node] = superstep

    def lost(self, superstep: int) -> list[int]:
        return [int(i) for i in
                np.nonzero(superstep - self.last_seen
                           > self.patience)[0]]
