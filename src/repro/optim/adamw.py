"""AdamW with decoupled weight decay, global-norm clipping and
optional f32 master copies for bf16 params. Pure pytree functions
(no optax dependency — the substrate is built in-repo per the brief)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_copy: bool = False    # keep f32 master when params are bf16
    state_dtype: Any = jnp.float32   # bf16 → halve m/v memory (§Perf)


class OptState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params
    master: Optional[Params]


def init(cfg: AdamWConfig, params: Params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    master = None
    if cfg.master_copy:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros), master=master)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(cfg: AdamWConfig, state: OptState, params: Params,
          grads: Params) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        ref = master if master is not None else p.astype(jnp.float32)
        delta = lr * ((m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
                      + cfg.weight_decay * ref)
        new_ref = ref - delta
        return (new_ref.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype), new_ref)

    if state.master is not None:
        out = jax.tree.map(upd, params, grads, state.mu, state.nu,
                           state.master)
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_master = None
    if state.master is not None:
        new_master = jax.tree.map(lambda t: t[3], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu,
                                master=new_master), \
        {"grad_norm": gnorm, "lr": lr}
