"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step on a
TPU v5e pod (constants per the assignment):

    compute    = FLOPs_per_chip      / 197e12  (bf16 MXU peak)
    memory     = HBM_bytes_per_chip  / 819e9   (HBM bandwidth)
    collective = wire_bytes_per_chip / 49.5e9  (ICI, per-link)

FLOPs / bytes come from ``compiled.cost_analysis()`` (per-partition
module). Collective bytes are NOT in cost_analysis: we parse the
optimized per-device HLO (``compiled.as_text()``) and accumulate ring-
model wire bytes for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, using each op's replica-group size:

    all-reduce      2·bytes·(n-1)/n        all-gather  out·(n-1)/n
    reduce-scatter  in·(n-1)/n             all-to-all  in·(n-1)/n
    collective-permute  bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 49.5e9              # bytes/s / link (~50 GB/s)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0          # ring-model bytes per chip
    payload_bytes: float = 0.0       # raw operand/result bytes
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    by_kind_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)


def _group_size(line: str, total_devices: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        num_groups, group_size = int(m.group(1)), int(m.group(2))
        return max(1, group_size)
    m = _LIST_GROUPS_RE.search(line)
    if m:
        first = m.group(1)
        return max(1, len([x for x in first.split(",") if x.strip()]))
    return total_devices


def parse_collectives(hlo_text: str, total_devices: int
                      ) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        head, _, rest = s.partition("=")
        rest = rest.strip()
        kind = None
        op = None
        for c in _COLLECTIVES:
            if f" {c}(" in f" {rest}":
                kind, op = c, c
                break
            if f" {c}-start(" in f" {rest}":
                kind, op = c, f"{c}-start"     # async form: count starts
                break
        if kind is None:
            continue
        # result type string sits between '=' and the op name
        m = re.match(rf"^(.*?)\s*{re.escape(op)}\(", rest)
        type_str = m.group(1) if m else ""
        bytes_ = _shape_bytes(type_str)
        if bytes_ == 0:
            continue
        n = _group_size(s, total_devices)
        ring = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            wire = 2.0 * bytes_ * ring
        elif kind == "all-gather":
            wire = bytes_ * ring            # bytes_ = gathered result
        elif kind == "reduce-scatter":
            wire = bytes_ * ring * n        # result is the shard
        elif kind == "all-to-all":
            wire = bytes_ * ring
        else:                               # collective-permute
            wire = float(bytes_)
        st.wire_bytes += wire
        st.payload_bytes += bytes_
        st.counts[kind] = st.counts.get(kind, 0) + 1
        st.by_kind_bytes[kind] = st.by_kind_bytes.get(kind, 0.0) + wire
    return st


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float               # 6·N·D (active) per chip-step
    useful_ratio: float              # MODEL_FLOPS / HLO_FLOPS
    collectives: CollectiveStats

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("collectives")
        d["collective_counts"] = self.collectives.counts
        d["collective_by_kind"] = self.collectives.by_kind_bytes
        return d


def analyze(cost: dict, hlo_text: str, *, chips: int,
            model_flops_total: float) -> Roofline:
    """cost: compiled.cost_analysis() (per-partition on SPMD modules)."""
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text, chips)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll.wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf_chip = model_flops_total / chips
    return Roofline(
        flops_per_chip=flops, hbm_bytes_per_chip=hbm,
        wire_bytes_per_chip=coll.wire_bytes,
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck,
        model_flops=mf_chip,
        useful_ratio=mf_chip / flops if flops else 0.0,
        collectives=coll)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for
    inference (D = tokens processed in the step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch                  # one token per sequence
    return 2.0 * n_active * tokens


# --------------------------------------------------------------------
# Analytic FLOP model
# --------------------------------------------------------------------
# `cost_analysis()['flops']` undercounts lax.scan bodies on the CPU
# backend (loop bodies are counted once, not × trip count) — measured
# factors up to ~30× on the 94-layer stacks. The roofline's compute
# term therefore also carries an *analytic* matmul count derived from
# the config: 2 FLOPs per active matmul parameter per token, plus
# attention score/weight terms, ×3 for the backward pass in training.

def analytic_flops(cfg, shape) -> float:
    """Total step FLOPs across the cluster (not per chip)."""
    hd = cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv_heads
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab
    L = cfg.n_layers

    # per-layer matmul params (active)
    def layer_params(j: int) -> float:
        p = 0.0
        from repro.models.decoder import layer_kind, ffn_kind
        kind = layer_kind(cfg, j % max(1, _period(cfg)))
        if kind in ("attn", "cross"):
            p += d * H * hd + 2 * d * KV * hd + H * hd * d
        elif kind == "mamba":
            di = cfg.ssm_expand * d
            p += 2 * d * di + di * 2 * cfg.ssm_state + di * d
        elif kind == "mlstm":
            p += 3 * d * H * hd + H * hd * d + d * H * hd
        elif kind == "slstm":
            p += 4 * d * H * hd + H * hd * 4 * hd + H * hd * d
        fk = ffn_kind(cfg, j % max(1, _period(cfg)))
        g = 2 if cfg.act == "swiglu" else 1
        if fk == "mlp":
            p += d * g * f + f * d
        elif fk == "moe":
            p += d * cfg.moe_experts                  # router
            p += cfg.moe_topk * (d * g * f + f * d)   # active experts
        return p

    n_matmul = sum(layer_params(j) for j in range(L))
    n_matmul += d * V                                  # unembed

    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, mult, s_eff = B * S, 3.0, S / 2
    elif shape.kind == "prefill":
        tokens, mult, s_eff = B * S, 1.0, S / 2
    else:                                              # decode
        tokens, mult, s_eff = B * 1, 1.0, S

    flops = 2.0 * n_matmul * tokens * mult
    # attention scores + weighted sum: 4·s_eff·H·hd per attn layer/token
    n_attn = sum(1 for j in range(L)
                 if _kind_of(cfg, j) in ("attn", "cross"))
    flops += 4.0 * s_eff * H * hd * n_attn * tokens * mult
    if cfg.family == "encdec":
        # encoder over audio tokens (self) + decoder cross-attention
        enc_tokens = B * cfg.n_audio_tokens
        flops += 2.0 * (cfg.enc_layers * (d * H * hd * 2 + 2 * d * KV
                                          * hd + d * 2 * f + f * d)
                        ) * enc_tokens * mult
    return flops


def _period(cfg) -> int:
    from repro.models.decoder import period
    return period(cfg)


def _kind_of(cfg, j) -> str:
    from repro.models.decoder import layer_kind
    return layer_kind(cfg, j % max(1, _period(cfg)))
